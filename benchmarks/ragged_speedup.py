"""Ragged capacity-bucket execution benchmark -> BENCH_ragged.json.

For each budget in a sweep, lowers the toy-config train-mode forward under
(a) the ragged capacity-bucket path and (b) the dense rank-masked reference
path, and records per-step lowered FLOPs (XLA cost analysis — the number the
CI FLOP gate asserts on) plus wall-clock of the jitted forward. Dense is the
pre-refactor behavior: every budget costs full-budget compute; ragged FLOPs
must track the budget.

Usage:
    python benchmarks/ragged_speedup.py [--smoke] [--out BENCH_ragged.json]

Emits the harness's `name,us_per_call,derived` rows and writes the JSON
artifact uploaded by CI next to BENCH_serving.json.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "benchmarks")
from common import emit, timed  # noqa: E402

from repro.configs.elasti_toy import toy_lm  # noqa: E402
from repro.core.policy import ElasticPolicy, ElasticSpec, ragged_bucket  # noqa: E402
from repro.launch.hloprof import lowered_flops  # noqa: E402
from repro.models import forward, model_init, router_init  # noqa: E402

BUDGETS = (1.0, 0.75, 0.5, 0.25)


def build(seq: int, batch: int, vocab: int, d_model: int, n_layers: int):
    cfg = dataclasses.replace(
        toy_lm(n_layers=n_layers, d_model=d_model, vocab=vocab),
        dtype="float32")
    spec = ElasticSpec(mha_token_routed=True, mlp_token_routed=True)
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, spec)
    rp = router_init(jax.random.fold_in(key, 1), cfg, spec)
    rng = np.random.default_rng(0)
    tokens = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32))}
    return cfg, spec, params, rp, tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--out", default="BENCH_ragged.json")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()
    seq = args.seq or (128 if args.smoke else 512)
    cfg, spec, params, rp, batch = build(
        seq, args.batch, vocab=256, d_model=128, n_layers=4)
    dense = dataclasses.replace(spec, routing_impl="dense_mask")

    def make_fwd(sp):
        def f(rp, batch, policy, bucket=None):
            return forward(params, rp, batch, cfg, sp, mode="train",
                           policy=policy, bucket=bucket)[0]
        return f

    f_ragged = make_fwd(spec)
    f_dense = make_fwd(dense)
    jit_ragged = jax.jit(f_ragged, static_argnames=("bucket",))
    jit_dense = jax.jit(f_dense, static_argnames=("bucket",))

    rows = []
    for b in BUDGETS:
        pol = jax.tree.map(jnp.asarray, ElasticPolicy.uniform(b))
        bkt = ragged_bucket(pol, seq)
        fl_r = lowered_flops(f_ragged, rp, batch, pol, bucket=bkt,
                             static_argnames=("bucket",))
        fl_d = lowered_flops(f_dense, rp, batch, pol,
                             static_argnames=("bucket",))
        _, us_r = timed(lambda: jit_ragged(rp, batch, pol, bucket=bkt))
        _, us_d = timed(lambda: jit_dense(rp, batch, pol))
        rows.append({"budget": b, "bucket": bkt, "seq": seq,
                     "flops_ragged": fl_r, "flops_dense": fl_d,
                     "us_ragged": us_r, "us_dense": us_d})
        emit(f"ragged_fwd_b{b:g}", us_r,
             f"{fl_r / 1e6:.1f}MF_vs_{fl_d / 1e6:.1f}MF_dense")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)

    base = rows[0]
    half = next(r for r in rows if r["budget"] == 0.5)
    ratio = half["flops_ragged"] / max(base["flops_ragged"], 1.0)
    flops = [r["flops_ragged"] for r in rows]
    assert flops == sorted(flops, reverse=True), \
        f"ragged FLOPs must decrease with budget: {flops}"
    assert ratio <= 0.7, f"budget-0.5 FLOP ratio {ratio:.3f} > 0.7"
    print(f"\nwrote {args.out}: budget-0.5 lowers {ratio:.2f}x the FLOPs of "
          f"budget-1.0 (dense reference is "
          f"{half['flops_dense'] / max(rows[0]['flops_dense'], 1.0):.2f}x)")


if __name__ == "__main__":
    main()
