"""Paper Fig. 7 / §5.2: Elasti-ViT — routing on ALL layers vs EVEN layers
only vs the LEARNED depth router, compared at matched compute saving.

Even-layer routing at capacity c' saves (1-c')/2 of block compute; all-layer
at capacity c saves (1-c). Matched pairs: all@c vs even@(2c-1).

The paper's even-layer variant is a FIXED structural skip: every token runs
odd layers densely and routes the even ones. The elastic depth router
(docs/elastic_policy.md) generalizes it — a per-(token, layer) learned skip
of the WHOLE block. At depth capacity d a token runs d of the layers, saving
(1-d) of block compute, so the matched third arm is depth@(1+c')/2: same
saving as even@c', but the router learns WHICH layers each token skips
instead of hard-coding the even ones.

Metric (same eval protocol for all three arms): cosine similarity between
student and teacher encoder outputs on held-out procedural images (paper
threshold: > 0.95)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, pretrained_vit_teacher
from repro.configs import ElasticConfig, get_config
from repro.data import procedural_images
from repro.models import forward, model_init, router_init
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.training import init_train_state, make_train_step

BATCH = 8


def _vit():
    return pretrained_vit_teacher()


def _batch(cfg, seed, cls=None):
    emb, _ = procedural_images(BATCH, cfg.n_image_tokens, cfg.d_frontend,
                               seed, class_id=cls)
    return {"embeds": jnp.asarray(emb)}


def train_and_eval(cfg, params, ecfg, steps=40, seed=0):
    # layer stacking depends on the routing period (all=1, even=2); restack
    # the SAME weights (model_init is key-deterministic per layer) to match.
    params = model_init(jax.random.PRNGKey(0), cfg, ecfg)
    rp = router_init(jax.random.PRNGKey(7 + seed), cfg, ecfg)
    state = init_train_state(rp)
    step_fn = jax.jit(make_train_step(cfg, ecfg,
                                      lr=cosine_schedule(3e-3, steps)))
    for i in range(steps):
        state, m = step_fn(state, params, _batch(cfg, i))
    # eval: cosine similarity to teacher on held-out images
    sims = []
    for i in range(4):
        b = _batch(cfg, 10_000 + i)
        t_out, _ = forward(params, None, b, cfg, ecfg, mode="base")
        s_out, _ = forward(params, state.router_params, b, cfg, ecfg,
                           mode="train")
        t, s = np.asarray(t_out, np.float64), np.asarray(s_out, np.float64)
        num = (t * s).sum(-1)
        den = np.linalg.norm(t, axis=-1) * np.linalg.norm(s, axis=-1) + 1e-9
        sims.append(float((num / den).mean()))
    return float(np.mean(sims)), state.router_params


def _ecfg(cap, layers):
    return ElasticConfig(
        mlp_token_capacity=cap, mha_token_capacity=cap,
        mha_head_topk=None, mlp_n_experts=None, mlp_expert_topk=None,
        lora_rank=0, layers=layers, distill_loss="cosine")


def _ecfg_depth(cap):
    """Learned whole-layer skip at depth capacity ``cap`` — the elastic
    generalization of the fixed even-layer variant."""
    return ElasticConfig(
        mlp_token_capacity=None, mha_token_capacity=None,
        depth_capacity=cap, mha_head_topk=None, mlp_n_experts=None,
        mlp_expert_topk=None, lora_rank=0, layers="all",
        distill_loss="cosine")


def main(steps: int = 40):
    cfg, params = _vit()
    for c_all, c_even in ((0.75, 0.5), (0.9, 0.8)):
        # matched saving: all@c saves 1-c; even@c' saves (1-c')/2;
        # depth@d saves 1-d  =>  d = (1+c')/2 matches even@c'
        c_depth = (1.0 + c_even) / 2.0
        t0 = time.perf_counter()
        sim_all, _ = train_and_eval(cfg, params, _ecfg(c_all, "all"), steps)
        sim_even, _ = train_and_eval(cfg, params, _ecfg(c_even, "even"), steps)
        sim_depth, _ = train_and_eval(cfg, params, _ecfg_depth(c_depth), steps)
        dt = (time.perf_counter() - t0) / (3 * steps) * 1e6
        emit(f"fig7_matched_saving_{1 - c_all:.2f}", dt,
             f"all@{c_all}={sim_all:.4f};even@{c_even}={sim_even:.4f};"
             f"depth@{c_depth:g}={sim_depth:.4f};"
             f"even_better={sim_even > sim_all};"
             f"depth_beats_even={sim_depth > sim_even}")


if __name__ == "__main__":
    main()
