"""Paper Fig. 8 / §5.2: robustness of learned Elasti-ViT routing to the
training data distribution.

Train N router instances on N disjoint image classes (stand-ins for the
ImageNet category subsets of [39]), then compare the instances' router
logits on SHARED held-out images:
  * pairwise cosine similarity matrix of per-patch router logits (paper:
    all high, same-class highest on the diagonal blocks);
  * patch-selection overlap (fraction of top-k patches agreed on by two
    instances at capacity 0.5) — the paper's heatmap reduced to a scalar.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, pretrained_vit_teacher
from repro.configs import ElasticConfig, get_config
from repro.data import procedural_images
from repro.models import forward, model_init, router_init
from repro.optim import cosine_schedule
from repro.training import init_train_state, make_train_step

BATCH, N_INST = 8, 4


def _ecfg():
    return ElasticConfig(
        mlp_token_capacity=0.35, mha_token_capacity=None, mha_head_topk=None,
        mlp_n_experts=None, mlp_expert_topk=None, lora_rank=0,
        distill_loss="cosine")


def _batch(cfg, seed, cls=None):
    emb, _ = procedural_images(BATCH, cfg.n_image_tokens, cfg.d_frontend,
                               seed, class_id=cls)
    return {"embeds": jnp.asarray(emb)}


def _train_instance(cfg, params, ecfg, cls: int, steps: int):
    rp = router_init(jax.random.PRNGKey(100 + cls), cfg, ecfg)
    state = init_train_state(rp)
    step_fn = jax.jit(make_train_step(cfg, ecfg,
                                      lr=cosine_schedule(3e-3, steps)))
    for i in range(steps):
        state, _ = step_fn(state, params, _batch(cfg, i, cls=cls))
    return state.router_params


def _router_logits(cfg, params, rp, batch):
    """Per-patch logits of every tok_mlp router on held-out images: run the
    frozen encoder layer-by-layer and apply each layer's router to its
    input hidden state (what the routed model actually scores)."""
    from repro.core.routing import token_logits
    from repro.models.layers import norm_apply
    from repro.models.model import build_pattern, _run_stack  # noqa
    # simple probe: apply every stacked router to the embedding-projected
    # input (layer-0 view) AND to the final hidden state; concatenate.
    x0 = batch["embeds"].astype(jnp.float32) @ params["in_proj"]
    xf, _ = forward(params, None, batch, cfg, None, mode="base")
    outs = []
    for stack in rp["scan"]:
        if "tok_mlp" not in stack:
            continue
        w = stack["tok_mlp"]["w"]          # (P, D) stacked per period
        b = stack["tok_mlp"]["b"]
        for j in range(w.shape[0]):
            outs.append(x0 @ w[j] + b[j])
            outs.append(xf.astype(jnp.float32) @ w[j] + b[j])
    return jnp.stack(outs, 0)              # (R, B, T)


def main(steps: int = 40):
    cfg, params = pretrained_vit_teacher()
    ecfg = _ecfg()
    t0 = time.perf_counter()
    instances = [_train_instance(cfg, params, ecfg, c, steps)
                 for c in range(N_INST)]
    dt = (time.perf_counter() - t0) / (N_INST * steps) * 1e6

    held = _batch(cfg, 77_000)              # shared held-out images
    logits = [np.asarray(_router_logits(cfg, params, rp, held)).ravel()
              for rp in instances]
    sims = np.zeros((N_INST, N_INST))
    for i in range(N_INST):
        for j in range(N_INST):
            a, b = logits[i], logits[j]
            sims[i, j] = float(a @ b / (np.linalg.norm(a)
                                        * np.linalg.norm(b) + 1e-9))
    off = sims[~np.eye(N_INST, dtype=bool)]
    emit("fig8_router_cosine_sim", dt,
         f"offdiag_mean={off.mean():.4f};offdiag_min={off.min():.4f};"
         f"robust={off.min() > 0.5}")

    # top-k patch selection overlap at capacity 0.5 (layer-0 router view)
    k = cfg.n_image_tokens // 2
    sel = []
    for rp in instances:
        lg = np.asarray(_router_logits(cfg, params, rp, held))[0]  # (B, T)
        sel.append(np.argsort(-lg, axis=-1)[:, :k])
    ov = []
    for i in range(N_INST):
        for j in range(i + 1, N_INST):
            for b in range(sel[i].shape[0]):
                ov.append(len(set(sel[i][b]) & set(sel[j][b])) / k)
    emit("fig8_patch_selection_overlap", 0.0,
         f"mean={np.mean(ov):.3f};chance={k / cfg.n_image_tokens:.3f};"
         f"above_chance={np.mean(ov) > k / cfg.n_image_tokens}")


if __name__ == "__main__":
    main()
