"""Paper Fig. 9 / §5.3: Elasti-VLM — image-token subset selection before the
language decoder, linear vs MLP router.

Teacher = small VLM pretrained on (procedural image, caption-chain) pairs;
router selects top-k image tokens (capacity = fraction kept). Metric: eval
LM loss of the elastic student vs the frozen teacher (stands in for
LLaVA-Bench score ratio). Expectation (paper): ~0.6-0.7 capacity matches the
teacher; the MLP router beats linear at equal capacity."""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import ElasticConfig, get_config
from repro.data import ZipfMarkov, procedural_images
from repro.models import forward, model_init, router_init
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.training import init_train_state, lm_loss, make_train_step

BATCH, SEQ = 8, 48
N_CLASSES = 2
VOCAB = 512
_CHAINS = {}


def _chain(cls: int, vocab: int) -> ZipfMarkov:
    """Each image class speaks its own Markov language — captioning REQUIRES
    reading the image tokens (otherwise token routing is unexercised)."""
    if cls not in _CHAINS:
        _CHAINS[cls] = ZipfMarkov(vocab, seed=1000 + cls)
    return _CHAINS[cls]


def _batch(cfg, step):
    emb, labels = procedural_images(BATCH, cfg.n_image_tokens,
                                    cfg.d_frontend, seed=step,
                                    n_classes=N_CLASSES)
    toks = np.concatenate(
        [_chain(int(c), cfg.vocab_size).sample(1, SEQ, stream_seed=step * BATCH + i)
         for i, c in enumerate(labels)], axis=0)
    return {"tokens": jnp.asarray(toks),
            "image_embeds": jnp.asarray(emb)}


@functools.lru_cache(maxsize=1)
def _teacher(steps: int = 500):
    cfg = dataclasses.replace(get_config("toy-vlm"), dtype="float32",
                              vocab_size=VOCAB)
    params = model_init(jax.random.PRNGKey(0), cfg, None)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        def loss_fn(p):
            logits, _ = forward(p, None, batch, cfg, None, mode="base")
            return lm_loss(logits, batch["tokens"])
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(g, opt, params,
                                      lr=cosine_schedule(3e-3, steps))
        return params, opt, loss

    for i in range(steps):
        params, opt, loss = step(params, opt, _batch(cfg, i))
    return cfg, params


def _distill(cfg, params, ecfg, steps):
    rp = router_init(jax.random.PRNGKey(7), cfg, ecfg)
    state = init_train_state(rp)
    step_fn = jax.jit(make_train_step(cfg, ecfg,
                                      lr=cosine_schedule(3e-3, steps)))
    for i in range(steps):
        state, m = step_fn(state, params, _batch(cfg, i))
    return state.router_params


def _eval(cfg, params, rp, ecfg, mode):
    losses = []
    for i in range(4):
        b = _batch(cfg, 5000 + i)
        logits, _ = forward(params, rp, b, cfg, ecfg, mode=mode)
        losses.append(float(lm_loss(logits, b["tokens"])))
    return float(np.mean(losses))


def main(steps: int = 40):
    cfg, params = _teacher()
    base = _eval(cfg, params, None, None, "base")
    emit("fig9_teacher_lm_loss", 0.0, f"{base:.4f}")
    for router in ("linear", "mlp"):
        for cap in (0.3, 0.6, 0.9):
            ecfg = ElasticConfig(
                mlp_token_capacity=None, mha_token_capacity=None,
                mha_head_topk=None, mlp_n_experts=None, mlp_expert_topk=None,
                vlm_token_capacity=cap, vlm_router=router, lora_rank=0)
            t0 = time.perf_counter()
            rp = _distill(cfg, params, ecfg, steps)
            dt = (time.perf_counter() - t0) / steps * 1e6
            loss = _eval(cfg, params, rp, ecfg, "train")
            emit(f"fig9_{router}_cap{cap}", dt,
                 f"lm_loss={loss:.4f};delta_vs_teacher={loss - base:+.4f}")


if __name__ == "__main__":
    main()
