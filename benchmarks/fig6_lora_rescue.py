"""Paper Fig. 6: LoRA adapters (q/v projections) rescue MHA input-subset
selection. Sweep LoRA rank {0, 1, 4} at token capacity {0.6, 0.8} with
token routing on BOTH MHA and MLP + expert selection (the paper's combined
Gemma-2 setting)."""
from __future__ import annotations

import time

from benchmarks.common import (distill_routers, emit, eval_lm_loss,
                               pretrained_teacher)
from repro.configs import ElasticConfig


def main(steps: int = 40):
    cfg, params = pretrained_teacher()
    teacher = eval_lm_loss(params, None, cfg, None, "base")
    emit("fig6_teacher", 0.0, f"lm_loss={teacher:.4f}")
    res = {}
    for cap in (0.6, 0.8):
        for rank in (0, 1, 4):
            ecfg = ElasticConfig(
                mlp_token_capacity=cap, mha_token_capacity=cap,
                mha_head_topk=None, mlp_n_experts=4, mlp_expert_topk=2,
                lora_rank=rank)
            t0 = time.perf_counter()
            rp, _ = distill_routers(params, cfg, ecfg, steps=steps)
            dt = (time.perf_counter() - t0) / steps * 1e6
            loss = eval_lm_loss(params, rp, cfg, ecfg, "train")
            res[(cap, rank)] = loss
            emit(f"fig6_cap{cap}_rank{rank}", dt,
                 f"eval_lm_loss={loss:.4f};gap={loss - teacher:+.4f}")
    for cap in (0.6, 0.8):
        emit(f"fig6_lora_gain_cap{cap}", 0.0,
             f"rank0={res[(cap, 0)]:.4f};rank1={res[(cap, 1)]:.4f};"
             f"rank4={res[(cap, 4)]:.4f}")


if __name__ == "__main__":
    main()
