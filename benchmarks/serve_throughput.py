"""Serving throughput: continuous batching vs lockstep under open-loop load.

Replays the same Poisson arrival schedule (2-3 rates x budget mixes on the
toy config) through two serving disciplines on identical model state:

  * continuous — ``engine.submit`` on arrival + ``engine.step`` slot
    scheduling: admissions overlap in-flight decode, freed slots refill.
  * lockstep   — the legacy pattern: form a batch from whatever has
    arrived, run ``generate()`` to completion, repeat. Arrivals during a
    batch wait for the next one.

Emits ``BENCH_serving.json`` rows {mode, arrival_rate, budgets, tok_s,
mean_ms, p50_ms, p95_ms, ttft_p50_ms, ttft_p95_ms, itl_mean_ms,
itl_p95_ms, occupancy} plus the harness `name,us_per_call,derived`
lines (us_per_call = microseconds per generated token).

Expected shape: continuous wins latency at every rate (no batch-formation
wait) and wins tok/s once arrivals are fast enough to keep slots occupied
(the staggered-arrival regime); at very low rates lockstep's fuller batches
can edge out raw tok/s — that idle-slot compute is the price of latency.

Run: PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import emit, toy_cfg
from repro.configs import ElasticConfig
from repro.launch.serve import latency_stats, open_loop
from repro.models import model_init, router_init
from repro.training import GenRequest, ServingEngine

ELASTIC = ElasticConfig(mlp_token_capacity=0.5, mha_token_capacity=0.5,
                        mha_head_topk=2, mlp_n_experts=4, mlp_expert_topk=2)


def make_requests(cfg, n, plen, max_new, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [GenRequest(rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
                       max_new,
                       budget=budgets[i % len(budgets)] if budgets else None,
                       seed=i)
            for i in range(n)]


def lockstep(engine, reqs, arrive):
    """Legacy serving discipline: batch whatever has arrived (up to the
    engine's slot count), run it to completion, repeat. Returns
    (n_tokens, elapsed, per-request latencies)."""
    B = engine.B
    t0 = time.perf_counter()
    i, n_tok, lat = 0, 0, []
    while i < len(reqs):
        now = time.perf_counter() - t0
        if arrive[i] > now:
            time.sleep(arrive[i] - now)
            now = time.perf_counter() - t0
        j = i
        while j < len(reqs) and j - i < B and arrive[j] <= now:
            j += 1
        outs = engine.generate([reqs[k] for k in range(i, j)])
        done = time.perf_counter() - t0
        lat += [done - arrive[k] for k in range(i, j)]
        n_tok += sum(len(o) for o in outs)
        i = j
    return n_tok, time.perf_counter() - t0, lat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests/steps)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    n, plen, max_new = (8, 8, 8) if args.smoke else (24, 12, 24)
    rates = (4.0, 16.0) if args.smoke else (2.0, 6.0, 16.0)
    budget_mixes = ([1.0], [0.4, 0.8]) if args.smoke else \
        ([1.0], [0.4, 0.8], [0.3, 0.5, 1.0])

    cfg = toy_cfg()
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg, ELASTIC)
    rp = router_init(jax.random.fold_in(key, 1), cfg, ELASTIC)
    max_seq = plen + max_new

    def engine():
        return ServingEngine(params, rp, cfg, ELASTIC, mode="infer",
                             batch_size=args.batch, max_seq=max_seq)

    cont, lock = engine(), engine()
    warm = make_requests(cfg, 1, plen, max_new, None)
    cont.generate(warm)
    lock.generate(warm)          # compile outside every timed window

    rows = []
    rng = np.random.default_rng(7)
    for rate in rates:
        for budgets in budget_mixes:
            reqs = make_requests(cfg, n, plen, max_new, budgets,
                                 seed=int(rate * 100) + len(budgets))
            arrive = np.cumsum(rng.exponential(1.0 / rate, n))

            cont.scheduler.reset_stats()
            handles, dt_c = open_loop(cont, reqs, rate, arrive=arrive)
            tok_c = sum(len(h.output) for h in handles)
            stats = latency_stats(handles)
            rows.append({"mode": "continuous", "arrival_rate": rate,
                         "budgets": budgets, "tok_s": tok_c / dt_c,
                         "occupancy": cont.occupancy, **stats})
            emit(f"serve_cont_r{rate:g}_b{len(budgets)}",
                 dt_c / max(tok_c, 1) * 1e6, f"{tok_c / dt_c:.1f}tok/s")

            tok_l, dt_l, lat = lockstep(lock, reqs, arrive)
            lat = np.asarray(lat)
            # lockstep has no per-token timestamps (generate() is opaque):
            # TTFT/ITL columns stay None so the row schema matches
            rows.append({"mode": "lockstep", "arrival_rate": rate,
                         "budgets": budgets, "tok_s": tok_l / dt_l,
                         "mean_ms": float(lat.mean() * 1e3),
                         "p50_ms": float(np.percentile(lat, 50) * 1e3),
                         "p95_ms": float(np.percentile(lat, 95) * 1e3),
                         "ttft_p50_ms": None, "ttft_p95_ms": None,
                         "itl_mean_ms": None, "itl_p95_ms": None,
                         "occupancy": None})
            emit(f"serve_lock_r{rate:g}_b{len(budgets)}",
                 dt_l / max(tok_l, 1) * 1e6, f"{tok_l / dt_l:.1f}tok/s")

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    # budgets/slots/sampling must never recompile across the whole sweep
    counts = cont.compile_counts()
    assert counts == {"prefill": 1, "decode": 1}, counts
    wins = sum(1 for c, l in zip(rows[::2], rows[1::2])
               if c["tok_s"] > l["tok_s"])
    print(f"\nwrote {args.out}: continuous beats lockstep in "
          f"{wins}/{len(rows) // 2} scenarios; compiles={counts}")


if __name__ == "__main__":
    main()
