"""Benchmark runner: one bench per paper table/figure (deliverable d).

Each bench prints ``name,us_per_call,derived`` CSV rows. Figure benches
pretrain a small teacher from scratch (cached under REPRO_BENCH_CACHE),
then apply ElastiFormer post-training exactly as the paper does.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig5,table1] [--fast]
"""
from __future__ import annotations

import argparse
import time
import traceback

BENCHES = [
    ("table1", "benchmarks.table1_params", {}),
    ("fig2", "benchmarks.fig2_pruning", {}),
    ("fig4", "benchmarks.fig4_distill_losses", {}),
    ("fig5", "benchmarks.fig5_capacity_scaling", {}),
    ("fig6", "benchmarks.fig6_lora_rescue", {}),
    ("fig7", "benchmarks.fig7_vit_even_layers", {}),
    ("fig8", "benchmarks.fig8_router_robustness", {}),
    ("fig9", "benchmarks.fig9_vlm", {}),
]

FAST_KW = {  # reduced step counts for smoke runs
    "fig2": {"fast": True},
    "fig4": {"steps": 12}, "fig5": {"steps": 10}, "fig6": {"steps": 10},
    "fig7": {"steps": 10}, "fig8": {"steps": 10}, "fig9": {"steps": 10},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (default: all)")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived", flush=True)
    failures = []
    for name, module, kw in BENCHES:
        if only and name not in only:
            continue
        if args.fast:
            kw = {**kw, **FAST_KW.get(name, {})}
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main(**kw)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001 — keep sweeping
            failures.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benches failed: {failures}")


if __name__ == "__main__":
    main()
